"""Roofline terms from a compiled dry-run artifact (no hardware needed).

Three terms per (arch x shape x mesh), TPU v5e constants:

  compute    = HLO_FLOPs(per device)  / peak_FLOPs            (197 TF bf16)
  memory     = HLO_bytes(per device)  / HBM_bw                (819 GB/s)
  collective = sum over collective ops of ring-wire bytes / link_bw (~50 GB/s)

`compiled.cost_analysis()` reports the per-device (post-SPMD) program, so the
first two terms need no further division by chip count. Collective bytes are
NOT in cost_analysis: we parse `compiled.as_text()` and sum operand sizes of
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
scaled by the ring-algorithm wire factor for the op's group size g:

  all-gather      (g-1)/g * result_bytes
  all-reduce    2*(g-1)/g * operand_bytes
  reduce-scatter  (g-1)/g * operand_bytes
  all-to-all      (g-1)/g * operand_bytes
  collective-permute   1 * operand_bytes

MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) gives the useful-compute
ratio (catches remat/redundancy waste).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

import numpy as np

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # B/s
ICI_LINK_BW = 50e9           # B/s per link; 1 ring direction per axis assumed

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %ag = bf16[4,1024]{1,0} all-gather(bf16[4,64]{1,0} %x), ...
_INSTR_RE = re.compile(
    r"=\s*(?:\([^)]*\)|(\w+)\[([\d,]*)\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_WHILE_RE = re.compile(
    r"while\(.*?\).*?condition=%?([\w\.\-]+).*?body=%?([\w\.\-]+)"
)
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _line_group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)   # replica_groups=[G,S]<=[N]
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST_RE.search(line)   # replica_groups={{0,1,..},..}
    if m:
        return len(m.group(1).split(","))
    return 2


@dataclasses.dataclass
class CollectiveStats:
    wire_bytes: float = 0.0
    op_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    op_count: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, kind: str, nbytes: float, group: int, mult: float = 1.0):
        if kind == "all-gather":
            wire = (group - 1) / group * nbytes
        elif kind == "all-reduce":
            wire = 2 * (group - 1) / group * nbytes
        elif kind in ("reduce-scatter", "all-to-all"):
            wire = (group - 1) / group * nbytes
        else:  # collective-permute
            wire = nbytes
        self.wire_bytes += wire * mult
        self.op_bytes[kind] = self.op_bytes.get(kind, 0.0) + nbytes * mult
        self.op_count[kind] = self.op_count.get(kind, 0) + mult


def _split_computations(hlo_text: str):
    """(name -> instruction lines, entry name). Headers sit at column 0 as
    `%name (params...) -> type {` or `ENTRY %name (...) {`."""
    comps: Dict[str, list] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        if line[:1] in ("%", "E") and line.rstrip().endswith("{"):
            head = line.strip()
            is_entry = head.startswith("ENTRY")
            if is_entry:
                head = head[len("ENTRY"):].strip()
            name = head.split(" (")[0].split()[0].lstrip("%")
            cur = name
            comps[cur] = []
            if is_entry:
                entry = name
        elif cur is not None:
            stripped = line.strip()
            if stripped and stripped != "}":
                comps[cur].append(stripped)
            elif stripped == "}":
                cur = None
    return comps, entry


def _trip_count(cond_lines) -> float:
    """Heuristic: jax scans lower to `iv < N` conditions — take the largest
    integer constant compared in the condition computation."""
    best = 1
    for line in cond_lines:
        if "compare(" in line or "constant(" in line:
            for m in _CONST_RE.finditer(line):
                best = max(best, int(m.group(1)))
    return float(best)


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Collective wire bytes, TRIP-COUNT AWARE.

    XLA's cost_analysis (and a naive text scan) counts a while-loop body
    once; scan-over-layers and microbatch accumulation mean almost all
    collectives live inside while bodies. We attribute each collective to
    its computation and multiply by the product of enclosing loop trip
    counts (parsed from the loop-condition constants)."""
    comps, entry = _split_computations(hlo_text)

    # Multiplier per computation: product of trip counts of enclosing whiles.
    mult: Dict[str, float] = {name: 0.0 for name in comps}
    if entry is None and comps:
        entry = list(comps)[-1]

    def visit(name: str, m: float, seen):
        if name not in comps or name in seen:
            return
        mult[name] = mult.get(name, 0.0) + m
        seen = seen | {name}
        for line in comps[name]:
            w = _WHILE_RE.search(line)
            if w:
                cond, body = w.group(1), w.group(2)
                trips = _trip_count(comps.get(cond, []))
                visit(body, m * trips, seen)
            else:
                # non-while calls (fusion bodies, called computations) keep m
                for cm in re.finditer(r"(?:calls=|to_apply=)%?([\w\.\-]+)",
                                      line):
                    visit(cm.group(1), m, seen)

    if entry is not None:
        visit(entry, 1.0, frozenset())

    stats = CollectiveStats()
    for name, lines in comps.items():
        m = mult.get(name, 0.0)
        if m <= 0:
            m = 1.0 if name == entry else 0.0
        if m == 0.0:
            continue
        for line in lines:
            im = _INSTR_RE.search(line)
            if not im:
                continue
            if "-done(" in line:  # async pair: count only the -start
                continue
            kind = im.group(3)
            dtype, dims = im.group(1), im.group(2)
            if dtype is None:
                best = 0
                for sm in _SHAPE_RE.finditer(line):
                    best = max(best, _shape_bytes(sm.group(1), sm.group(2)))
                nbytes = best
            else:
                nbytes = _shape_bytes(dtype, dims)
            # The CPU backend PROMOTES bf16 all-reduces to f32
            # (`to_apply=%add..._promoted`); the TPU target reduces in bf16,
            # so count the un-promoted width.
            if "_promoted" in line and (dtype == "f32" or dtype is None):
                nbytes /= 2.0
            stats.add(kind, float(nbytes), _line_group_size(line), m)
    return stats


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float            # per device
    hlo_bytes: float            # per device
    wire_bytes: float           # per device, ring-factored
    model_flops: Optional[float] = None   # 6*N*D useful flops (global)
    peak_mem_bytes: Optional[float] = None

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.wire_bytes / ICI_LINK_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_ratio(self) -> Optional[float]:
        """MODEL_FLOPS / (chips * HLO_FLOPs-per-device)."""
        if not self.model_flops or not self.hlo_flops:
            return None
        return self.model_flops / (self.chips * self.hlo_flops)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the binding roofline actually used by useful work:
        time the dominant resource *must* take for the useful work, divided
        by the time the compiled program claims on that resource."""
        t = self.bound_time
        if t <= 0:
            return 0.0
        if self.model_flops:
            useful_t = self.model_flops / self.chips / PEAK_FLOPS
            return min(useful_t / t, 1.0)
        return self.t_compute / t

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops_per_dev": self.hlo_flops,
            "hlo_bytes_per_dev": self.hlo_bytes,
            "wire_bytes_per_dev": self.wire_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
            "peak_mem_bytes": self.peak_mem_bytes,
        }


def model_flops_for(cfg, shape_info: dict, n_active_params: int) -> float:
    """6*N*D per processed token (train fwd+bwd); 2*N*D for inference."""
    tokens = shape_info["batch"] * shape_info["seq"]
    if shape_info["kind"] == "train":
        return 6.0 * n_active_params * tokens
    if shape_info["kind"] == "prefill":
        return 2.0 * n_active_params * tokens
    # decode: one token per sequence in the batch
    return 2.0 * n_active_params * shape_info["batch"]


# ---------------------------------------------------------------------------
# Analytic per-device cost model (primary source for the compute/memory
# roofline terms).
#
# WHY ANALYTIC: XLA's HloCostAnalysis counts a while-loop body ONCE; with
# scan-over-layers + microbatch scans, compiled.cost_analysis() undercounts
# FLOPs by ~(layers x microbatches). We therefore derive FLOPs/bytes from
# the architecture formulas below (matmul-exact, attention/SSD included,
# remat multiplier applied) and keep the raw cost_analysis numbers in the
# record as `hlo_reported_*` for reference. Collective bytes ARE parsed from
# the compiled HLO (trip-count aware, see collective_stats).
# ---------------------------------------------------------------------------

def _attn_flops_per_token(cfg, s_kv: float) -> float:
    hd = cfg.resolved_head_dim
    h, k, d = cfg.num_heads, cfg.num_kv_heads, cfg.d_model
    proj = 2.0 * d * (h + 2 * k) * hd + 2.0 * h * hd * d
    score = 4.0 * h * hd * s_kv                 # qk^T and pv
    return proj + score


def _ssm_flops_per_token(cfg, decode: bool) -> float:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    h = d_in // s.head_dim
    p, n, q = s.head_dim, s.d_state, s.chunk
    proj = 2.0 * d * (2 * d_in + 2 * n + h) + 2.0 * d_in * d
    conv = 2.0 * s.d_conv * (d_in + 2 * n)
    if decode:
        ssd = 6.0 * h * p * n
    else:
        ssd = 2.0 * q * n + 2.0 * q * h * p + 4.0 * n * h * p
    return proj + conv + ssd


def _ffn_flops_per_token(cfg, sub) -> float:
    d = cfg.d_model
    if sub.ffn == "mlp":
        return (6.0 if cfg.mlp_type == "swiglu" else 4.0) * d * cfg.d_ff
    if sub.ffn == "moe":
        m = cfg.moe
        routed = m.top_k * m.capacity_factor * 6.0 * d * m.d_ff_expert
        shared = m.num_shared_experts * 6.0 * d * m.d_ff_shared
        return routed + shared + 2.0 * d * m.num_experts
    return 0.0


def forward_flops_per_token(cfg, s_kv: float, decode: bool = False) -> float:
    total = 0.0
    for sub in cfg.pattern:
        if sub.kind == "attn":
            kv = s_kv
            if cfg.sliding_window is not None:
                kv = min(kv, cfg.sliding_window)
            total += _attn_flops_per_token(cfg, kv)
        else:
            total += _ssm_flops_per_token(cfg, decode)
        total += _ffn_flops_per_token(cfg, sub)
    total *= cfg.repeats
    # head (audio: K heads)
    k = cfg.frontend.num_positions if (
        cfg.frontend and cfg.frontend.modality == "audio") else 1
    total += 2.0 * cfg.d_model * cfg.vocab_size * k
    return total


@dataclasses.dataclass
class AnalyticCosts:
    flops_per_dev: float
    hbm_bytes_per_dev: float


def analytic_costs(cfg, shape_info: dict, chips: int,
                   param_count: int, microbatches: int = 8,
                   remat: bool = True, tp: int = 16,
                   fsdp: bool = True, zero3_gather: bool = True,
                   moe_ep: bool = False) -> AnalyticCosts:
    """Per-device FLOPs and HBM bytes for one step of the cell.

    Accounting (documented assumptions):
      * FLOPs are balanced across chips: per-device = global / chips.
      * Weight stream per device: with FSDP the all-gathered bf16 weights
        pass through every device's HBM once per use (full P bytes); without
        FSDP only the TP shard streams (P/tp bytes).
      * train: fwd+bwd = 3x fwd matmul FLOPs; full remat re-runs fwd -> 4x;
        weights stream once per microbatch per pass (fwd, remat-fwd, bwd);
        optimizer: m, v, master in f32, read+write, on the 1/chips shard.
      * activations: ~16 bytes/token/d_model residual-path round-trip per
        sub-layer, sharded across chips.
      * decode: KV cache (or SSM state) read once per token step.
    """
    kind = shape_info["kind"]
    seq = shape_info["seq"]
    batch = shape_info["batch"]
    dtype_b = 2.0  # bf16
    from repro.models.config import count_moe_expert_params
    p_moe = count_moe_expert_params(cfg) if moe_ep else 0
    p_dense = param_count - p_moe
    if fsdp and zero3_gather:
        # gathered weights stream fully through each device's HBM
        p_stream = p_dense * dtype_b + p_moe * dtype_b / tp
    elif fsdp:
        # no gather: each device reads only its 1/chips shard
        p_stream = param_count * dtype_b / chips
    else:
        p_stream = param_count * dtype_b / tp

    if kind == "decode":
        tokens = float(batch)
        f = forward_flops_per_token(cfg, s_kv=seq, decode=True) * tokens
        hd = cfg.resolved_head_dim
        n_attn = cfg.repeats * sum(1 for s in cfg.pattern if s.kind == "attn")
        s_alloc = min(seq, cfg.sliding_window) if cfg.sliding_window else seq
        kv_bytes = 2 * n_attn * batch * s_alloc * cfg.num_kv_heads * hd * dtype_b
        ssm_bytes = 0.0
        if cfg.ssm:
            d_in = cfg.ssm.expand * cfg.d_model
            h = d_in // cfg.ssm.head_dim
            n_ssm = cfg.repeats * sum(1 for s in cfg.pattern if s.kind == "ssm")
            ssm_bytes = 2 * n_ssm * batch * h * cfg.ssm.head_dim \
                * cfg.ssm.d_state * 4
        act = 16.0 * tokens * cfg.d_model * cfg.num_layers
        bytes_dev = p_stream + (kv_bytes + ssm_bytes + act) / chips
        return AnalyticCosts(f / chips, bytes_dev)

    tokens = float(batch) * seq
    s_kv = (seq + 1) / 2.0  # causal average
    f_fwd = forward_flops_per_token(cfg, s_kv=s_kv) * tokens
    act_bytes = 16.0 * tokens * cfg.d_model * cfg.num_layers
    logits_bytes = 4.0 * tokens * cfg.vocab_size  # f32 logits r/w
    if kind == "prefill":
        bytes_dev = p_stream + (act_bytes + logits_bytes / seq) / chips
        return AnalyticCosts(f_fwd / chips, bytes_dev)

    # train
    flops = (4.0 if remat else 3.0) * f_fwd
    passes = (3.0 if remat else 2.0)
    opt_bytes = param_count * 4.0 * 6.0 / chips
    bytes_dev = (microbatches * passes * p_stream
                 + (passes * act_bytes + 2 * logits_bytes) / chips + opt_bytes)
    return AnalyticCosts(flops / chips, bytes_dev)
