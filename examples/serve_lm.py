"""Serve a small model with batched requests: prefill + greedy decode.

    PYTHONPATH=src python examples/serve_lm.py --arch mixtral-8x7b
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.data import synthetic_batch
from repro.models.transformer import init_params
from repro.serving import greedy_generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=16)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = synthetic_batch(cfg, args.batch, args.prompt_len,
                            jax.random.PRNGKey(1))
    prompt = {"tokens": batch["tokens"]}
    if "patch_embeds" in batch:
        prompt["patch_embeds"] = batch["patch_embeds"]

    t0 = time.perf_counter()
    out = greedy_generate(cfg, params, prompt, steps=args.steps,
                          s_max=args.prompt_len + args.steps + 8)
    dt = time.perf_counter() - t0
    toks = np.array(out)
    print(f"served {args.batch} requests x {args.steps} tokens "
          f"in {dt:.2f}s ({args.batch * args.steps / dt:.1f} tok/s on CPU)")
    print("first request's generated ids:", toks[0].tolist()[:12], "...")


if __name__ == "__main__":
    main()
