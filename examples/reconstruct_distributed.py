"""End-to-end driver: DISTRIBUTED iFDK reconstruction with fault injection.

Runs the paper's full pipeline on a virtual 8-device mesh (2 pods x 2 data x
2 model): per-rank load+filter, column AllGather, slab back-projection, row
reduce-scatter — then demonstrates checkpoint/restart by killing the job
mid-stream and resuming.

    PYTHONPATH=src python examples/reconstruct_distributed.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core.distributed import input_sharding
from repro.core.fdk import fdk_scale, gups
from repro.core.geometry import default_geometry
from repro.core.phantom import forward_project
from repro.core.plan import ReconstructionPlan, plan_from_spec
from repro.parallel.mesh import make_mesh
from repro.planner import search_plans
from repro.runtime import ResumableReconstruction, StragglerMonitor


def main():
    g = default_geometry(32, n_proj=64)
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    print(f"mesh: {dict(mesh.shape)}  problem: "
          f"{g.n_u}^2 x {g.n_proj} -> {g.n_x}^3")

    proj = forward_project(g)
    # Auto-planning: the planner (repro/planner) prices the schedule x
    # reduce x precision cross-product with the paper's Eq. 8-19 model,
    # prunes what cannot fit in HBM, and hands back the best feasible plan.
    for i, p in enumerate(search_plans(g, mesh, top_k=3)):
        print(f"  candidate {i}: {p.spec()}  "
              f"t_run={p.breakdown.t_runtime:.3f}s  "
              f"footprint={p.footprint.total / 2**20:.0f}MiB")
    plan = plan_from_spec(g, "auto,precision=fp32", mesh=mesh)
    print(f"auto plan: {plan.describe()}")
    fn = plan.build()
    out = fn(jax.device_put(proj, input_sharding(mesh)))
    vol = np.array(out).reshape(g.n_x, g.n_y, g.n_z)
    ref = np.array(ReconstructionPlan(geometry=g).build()(proj))
    print(f"distributed vs single-device max err: "
          f"{np.max(np.abs(vol - ref)):.2e}")

    # --- fault-tolerant micro-batched reconstruction -----------------------
    import time
    from repro.core.backprojection import backproject_factorized
    from repro.core.filtering import filter_projections
    from repro.core.geometry import projection_matrices

    pm = jnp.asarray(projection_matrices(g))
    q = filter_projections(g, proj)
    nb, bsz = 8, g.n_proj // 8

    def step_fn(acc, bi):
        lo = bi * bsz
        return acc + backproject_factorized(
            pm[lo:lo + bsz], q[lo:lo + bsz], g.n_x, g.n_y, g.n_z
        )

    with tempfile.TemporaryDirectory() as ckdir:
        mgr = CheckpointManager(ckdir)
        r = ResumableReconstruction(step_fn, jnp.zeros(g.volume_shape()),
                                    nb, mgr, checkpoint_every=2)
        try:
            r.run(fail_at=5)
        except RuntimeError as e:
            print(f"injected fault: {e} -> restarting from checkpoint")
        r2 = ResumableReconstruction(step_fn, jnp.zeros(g.volume_shape()),
                                     nb, mgr, checkpoint_every=2)
        r2.resume()
        print(f"resumed at micro-batch {r2.state.cursor}/{nb}")
        t0 = time.perf_counter()
        acc = r2.run()
        dt = time.perf_counter() - t0
        vol2 = np.array(acc) * fdk_scale(g)
        print(f"recovered reconstruction max err: "
              f"{np.max(np.abs(vol2 - ref)):.2e} "
              f"({gups(g, dt):.3f} GUPS for the resumed half)")

    mon = StragglerMonitor()
    for t in (1.0, 1.02, 0.98, 3.0, 1.01):
        mon.record(t)
    print(f"straggler monitor flagged steps: {mon.flagged}; "
          f"rebalance hint: {mon.rebalance_hint(nb, 8)}")


if __name__ == "__main__":
    main()
