"""Quickstart: reconstruct a Shepp-Logan phantom with iFDK in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp

from repro.core.fdk import gups
from repro.core.geometry import default_geometry
from repro.core.phantom import forward_project, shepp_logan_volume
from repro.core.plan import ReconstructionPlan


def main():
    # 64^3 volume from 128 cone-beam projections of the 3-D Shepp-Logan
    g = default_geometry(64, n_proj=128)
    print(f"geometry: {g.n_u}x{g.n_v}x{g.n_proj} -> "
          f"{g.n_x}x{g.n_y}x{g.n_z}")

    projections = forward_project(g)           # analytic X-ray simulator

    # One declarative plan = the whole pipeline (filter -> back-project ->
    # scale); .build() validates, tunes and jits it once.
    plan = ReconstructionPlan(geometry=g, impl="factorized")
    fdk = plan.build()
    t0 = time.perf_counter()
    vol = jax.block_until_ready(fdk(projections))
    seconds = time.perf_counter() - t0
    print(f"plan {plan.describe()}")
    print(f"reconstructed in {seconds:.2f}s "
          f"({gups(g, seconds):.3f} GUPS on CPU)")

    phantom = shepp_logan_volume(g)
    m = g.n_x // 5
    inner = (slice(m, g.n_x - m),) * 3
    rmse = float(jnp.sqrt(jnp.mean((vol[inner] - phantom[inner]) ** 2)))
    print(f"interior RMSE vs phantom: {rmse:.4f}")

    # the paper's validation: factorized (Alg.4) == reference (Alg.2) —
    # the same plan at another impl point
    ref = ReconstructionPlan(geometry=g, impl="reference").build()(projections)
    err = float(jnp.max(jnp.abs(ref - vol))) / float(jnp.max(jnp.abs(ref)))
    print(f"Alg.4 vs Alg.2 relative max err: {err:.2e} (paper bound: 1e-5 RMSE)")


if __name__ == "__main__":
    main()
