"""Quickstart: reconstruct a Shepp-Logan phantom with iFDK in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.core.fdk import reconstruct, timed_reconstruct
from repro.core.geometry import default_geometry
from repro.core.phantom import forward_project, shepp_logan_volume


def main():
    # 64^3 volume from 128 cone-beam projections of the 3-D Shepp-Logan
    g = default_geometry(64, n_proj=128)
    print(f"geometry: {g.n_u}x{g.n_v}x{g.n_proj} -> "
          f"{g.n_x}x{g.n_y}x{g.n_z}")

    projections = forward_project(g)           # analytic X-ray simulator
    vol, seconds, rate = timed_reconstruct(
        g, projections, impl="factorized", iters=1
    )
    print(f"reconstructed in {seconds:.2f}s ({rate:.3f} GUPS on CPU)")

    phantom = shepp_logan_volume(g)
    m = g.n_x // 5
    inner = (slice(m, g.n_x - m),) * 3
    rmse = float(jnp.sqrt(jnp.mean((vol[inner] - phantom[inner]) ** 2)))
    print(f"interior RMSE vs phantom: {rmse:.4f}")

    # the paper's validation: factorized (Alg.4) == reference (Alg.2)
    ref = reconstruct(g, projections, impl="reference")
    err = float(jnp.max(jnp.abs(ref - vol))) / float(jnp.max(jnp.abs(ref)))
    print(f"Alg.4 vs Alg.2 relative max err: {err:.2e} (paper bound: 1e-5 RMSE)")


if __name__ == "__main__":
    main()
