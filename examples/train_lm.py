"""Train a (reduced) assigned architecture for a few hundred steps on CPU.

    PYTHONPATH=src python examples/train_lm.py --arch qwen2-1.5b --steps 200

Uses the same train_step, data pipeline, checkpointing and straggler monitor
as the production launcher — just with the smoke-scale config.
"""
import argparse
import tempfile
import time

import jax

from repro.checkpoint import CheckpointManager
from repro.configs import get_smoke_config, list_archs
from repro.data import SyntheticTokens
from repro.runtime import StragglerMonitor
from repro.training import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=list_archs()
                    + ["qwen2-1.5b"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    print(f"training reduced {cfg.name}: {cfg.num_layers}L d={cfg.d_model}")
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, microbatches=2, warmup=20,
                                   total_steps=args.steps))
    data = SyntheticTokens(cfg, args.batch, args.seq, seed=0)
    mon = StragglerMonitor()

    with tempfile.TemporaryDirectory() as ckdir:
        mgr = CheckpointManager(ckdir, keep=2)
        t0 = time.perf_counter()
        for i in range(args.steps):
            state, metrics = step(state, data(i))
            jax.block_until_ready(metrics["loss"])
            mon.record(time.perf_counter() - t0)
            t0 = time.perf_counter()
            if i % 25 == 0 or i == args.steps - 1:
                print(f"step {i:4d}  loss {float(metrics['loss']):.4f}  "
                      f"gnorm {float(metrics['grad_norm']):.3f}")
            if (i + 1) % 100 == 0:
                mgr.save(i + 1, state)   # async checkpoint
        mgr.wait()
        print(f"stragglers flagged: {len(mon.flagged)}")


if __name__ == "__main__":
    main()
